"""Sharded tile harvest (``repro.scale.shard``) vs serial tiled vs dense.

The contract is the tentpole invariant: the mesh-sharded harvest must be
**bit-identical** to the serial tiled build and to dense ``build_filtration``
for every shard/device count.  The host-partitioned numpy path reproduces
any device count's work split without devices, so the identity sweep always
runs; the ``shard_map`` device path is parametrized over 1/2/4 devices and
skips the counts the process doesn't have (CI runs a job under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so they all run
there).  Per-device memory accounting is asserted against
``scale.budget``'s a-priori bounds.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import compute_ph
from repro.core.filtration import build_filtration, pairwise_distances
from repro.scale import (TileStats, build_filtration_sharded,
                         build_filtration_tiled, estimate_tau_max,
                         harvest_edges, harvest_edges_sharded,
                         partition_tiles, sharded_edge_budget, tile_grid,
                         tile_transient_bytes)

FILT_FIELDS = ("edges", "edge_len", "degree", "nbr_vtx", "nbr_vtx_ord",
               "nbr_edge_ord", "nbr_edge_vtx")


def assert_filtrations_identical(a, b, label=""):
    assert a.n == b.n, label
    assert a.n_e == b.n_e, (label, a.n_e, b.n_e)
    for f in FILT_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), (label, f)


def _data_mesh(n_devices):
    import jax

    if len(jax.devices()) < n_devices:
        pytest.skip(f"needs {n_devices} devices (run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={n_devices})")
    from repro.launch.mesh import make_data_mesh
    return make_data_mesh(n_devices)


# ---------------------------------------------------------------------------
# tile partition invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.data())
def test_partition_covers_grid_exactly_once(data):
    n = data.draw(st.integers(0, 300), label="n")
    tile_m = data.draw(st.sampled_from([3, 16, 64, 257]), label="tile_m")
    tile_n = data.draw(st.sampled_from([4, 23, 128]), label="tile_n")
    n_shards = data.draw(st.integers(1, 7), label="n_shards")
    tiles = tile_grid(n, tile_m, tile_n)
    shards = partition_tiles(n, tile_m, tile_n, n_shards)
    assert len(shards) == n_shards
    flat = [t for s in shards for t in s]
    assert sorted(flat) == sorted(tiles)            # disjoint exact cover
    assert len(set(flat)) == len(flat)
    # round-robin balance: shard sizes differ by at most one tile
    sizes = [len(s) for s in shards]
    assert max(sizes) - min(sizes) <= 1


def test_tile_grid_covers_all_pairs():
    n, tm, tn = 57, 13, 9
    seen = np.zeros((n, n), dtype=int)
    for si, sj in tile_grid(n, tm, tn):
        ei, ej = min(si + tm, n), min(sj + tn, n)
        ii, jj = np.meshgrid(np.arange(si, ei), np.arange(sj, ej),
                             indexing="ij")
        m = ii < jj
        seen[ii[m], jj[m]] += 1
    iu, ju = np.triu_indices(n, k=1)
    assert np.all(seen[iu, ju] == 1)                # each pair exactly once
    assert seen.sum() == len(iu)


def test_partition_rejects_bad_shard_count():
    with pytest.raises(ValueError):
        partition_tiles(10, 4, 4, 0)


# ---------------------------------------------------------------------------
# bit-identity: host-partitioned shards (any count, no devices needed)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.data())
def test_sharded_numpy_bit_identical_to_serial_and_dense(data):
    n = data.draw(st.integers(2, 120), label="n")
    d = data.draw(st.integers(1, 4), label="d")
    tile_m = data.draw(st.sampled_from([7, 16, 37, 256]), label="tile_m")
    tile_n = data.draw(st.sampled_from([5, 23, 64]), label="tile_n")
    n_shards = data.draw(st.sampled_from([1, 2, 3, 4, 8]), label="n_shards")
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16), label="seed"))
    pts = rng.normal(size=(n, d))
    if n >= 4:                                      # distance ties
        pts[n // 2] = pts[0]
    tau = data.draw(st.sampled_from([np.inf, 1.0, 2.0]), label="tau")

    dense = build_filtration(points=pts, tau_max=tau)
    serial = build_filtration_tiled(points=pts, tau_max=tau, tile_m=tile_m,
                                    tile_n=tile_n, backend="numpy")
    sharded, stats = build_filtration_sharded(
        points=pts, tau_max=tau, tile_m=tile_m, tile_n=tile_n,
        n_shards=n_shards, backend="numpy", return_stats=True)
    assert_filtrations_identical(dense, serial, "serial vs dense")
    assert_filtrations_identical(serial, sharded,
                                 f"sharded[{n_shards}] vs serial")
    assert sharded.dense_order is None
    assert stats.n_shards == n_shards
    assert stats.tiles_visited == len(tile_grid(n, tile_m, tile_n))


def test_sharded_dists_matrix_matches_dense():
    rng = np.random.default_rng(1)
    pts = rng.normal(size=(64, 3))
    dmat = pairwise_distances(pts)
    tau = float(np.quantile(dmat[np.triu_indices(64, k=1)], 0.5))
    dense = build_filtration(dists=dmat, tau_max=tau)
    for k in (1, 3):
        sharded = build_filtration_sharded(dists=dmat, tau_max=tau,
                                           tile_m=17, tile_n=29, n_shards=k)
        assert_filtrations_identical(dense, sharded, f"dists shards={k}")


def test_sharded_harvest_matches_serial_harvest_arrays():
    rng = np.random.default_rng(2)
    pts = rng.normal(size=(90, 3))
    ref = harvest_edges(points=pts, tau_max=1.5, tile_m=32, tile_n=32,
                        backend="numpy")
    got = harvest_edges_sharded(points=pts, tau_max=1.5, tile_m=32, tile_n=32,
                                n_shards=4, backend="numpy")
    for a, b in zip(ref, got):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# bit-identity: shard_map device path (1/2/4 virtual devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_sharded_mesh_bit_identical(n_devices):
    mesh = _data_mesh(n_devices)
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(130, 4)) * 5.0       # larger scale stresses margin
    tau = 6.0
    dense = build_filtration(points=pts, tau_max=tau)
    sharded, stats = build_filtration_sharded(
        points=pts, tau_max=tau, tile_m=48, tile_n=64, mesh=mesh,
        backend="pallas", interpret=True, return_stats=True)
    assert_filtrations_identical(dense, sharded, f"mesh[{n_devices}]")
    assert stats.n_shards == n_devices
    assert stats.mesh_axis == "data"
    assert stats.backend == "pallas"
    assert stats.candidate_pairs >= dense.n_e   # filter over-, never under-
    assert stats.gather_bytes > 0               # round stack was accounted


@pytest.mark.parametrize("n_devices", [2, 4])
def test_sharded_mesh_per_device_budget_respected(n_devices):
    """Per-device peak (TileStats) must land under the a-priori per-device
    budget that ``estimate_tau_max``'s sharded account reserved."""
    mesh = _data_mesh(n_devices)
    rng = np.random.default_rng(7)
    pts = rng.normal(size=(400, 3))
    tile = 64
    budget = 220_000                            # per device
    tau = estimate_tau_max(pts, budget, n_shards=n_devices,
                           tile_m=tile, tile_n=tile, seed=0)
    assert np.isfinite(tau) and tau > 0
    filt, stats = build_filtration_sharded(
        points=pts, tau_max=tau, tile_m=tile, tile_n=tile, mesh=mesh,
        backend="pallas", interpret=True, return_stats=True)
    # a-priori transient bound holds a posteriori (f32 path is smaller than
    # the numpy bound used by the account; fragments ride the edge share)
    transient = tile_transient_bytes(tile, tile, n_devices)
    assert stats.peak_tile_bytes + stats.gather_bytes <= transient
    # per-device account: duplicated vertex arrays + edge share under budget
    assert stats.per_device_base_bytes() <= 1.15 * budget
    # the global edge count respects the sharded (scaled) account
    global_edges = sharded_edge_budget(len(pts), budget, n_devices,
                                       tile, tile)
    assert filt.n_e <= 1.1 * global_edges + 16


def test_per_device_stats_numpy_path():
    """Host-partitioned path fills the same per-device accounting fields."""
    pts = np.random.default_rng(5).normal(size=(200, 3))
    _, stats = build_filtration_sharded(
        points=pts, tau_max=1.0, tile_m=64, tile_n=64, n_shards=4,
        backend="numpy", return_stats=True)
    assert stats.per_device_peak_bytes() >= stats.peak_tile_bytes
    assert stats.shard_peak_harvest_bytes > 0
    # per-shard fragments are a fraction of the whole harvest
    assert stats.shard_peak_harvest_bytes < stats.harvest_bytes
    assert stats.per_device_base_bytes() < stats.base_memory_bytes


# ---------------------------------------------------------------------------
# budget accounting (scale.budget sharded forms)
# ---------------------------------------------------------------------------

def test_tile_transient_bytes_accounts_gather():
    serial = tile_transient_bytes(64, 64, n_shards=1)
    sharded = tile_transient_bytes(64, 64, n_shards=4)
    assert sharded > serial                     # gather stack is charged
    assert sharded - serial >= 4 * 64 * 64 * 4  # >= D f32 output tiles
    # the stacked input blocks scale with the real point dimension
    assert tile_transient_bytes(64, 64, n_shards=4, d=32) \
        == sharded + 4 * (64 + 64) * (32 - 8) * 4


@pytest.mark.parametrize("n_devices", [2])
def test_sharded_mesh_wide_points_bound_holds(n_devices):
    """d > 8 clouds: the a-priori transient bound must use the real point
    dimension (regression — a hardcoded d=8 under-reserved the gather)."""
    mesh = _data_mesh(n_devices)
    rng = np.random.default_rng(17)
    pts = rng.normal(size=(150, 32))
    _, stats = build_filtration_sharded(
        points=pts, tau_max=4.0, tile_m=64, tile_n=64, mesh=mesh,
        backend="pallas", interpret=True, return_stats=True)
    bound = tile_transient_bytes(64, 64, n_shards=n_devices, d=32)
    assert stats.peak_tile_bytes + stats.gather_bytes <= bound


def test_mesh_and_conflicting_n_shards_rejected():
    mesh = _data_mesh(1)
    pts = np.zeros((8, 2))
    with pytest.raises(ValueError):
        harvest_edges_sharded(points=pts, mesh=mesh, n_shards=3,
                              tile_m=4, tile_n=4)
    # agreeing values are fine
    iu, _, _ = harvest_edges_sharded(points=pts, mesh=mesh, n_shards=1,
                                     tile_m=4, tile_n=4)
    assert iu.size == 0 or iu.ndim == 1


def test_sharded_edge_budget_scales_and_guards():
    n = 10_000
    per_dev = 20_000_000                        # budget >> tile transient
    e1 = sharded_edge_budget(n, per_dev, 1, 256, 256)
    e4 = sharded_edge_budget(n, per_dev, 4, 256, 256)
    assert e4 > e1                              # fleet affords more edges
    assert e4 <= 4 * e1                         # but pays vertex duplication
    with pytest.raises(ValueError):
        sharded_edge_budget(n, 1000, 4, 1024, 1024)   # tile doesn't even fit


def test_estimate_tau_max_sharded_needs_tiles_and_shrinks():
    pts = np.random.default_rng(0).normal(size=(300, 3))
    with pytest.raises(ValueError):
        estimate_tau_max(pts, 100_000, n_shards=2)    # tile dims required
    # the sharded account charges tile + gather per device before scaling
    # the edge share up by the device count (the serial form charged
    # nothing, under-reserving on every device of a mesh); whether the net
    # tau lands above or below the serial estimate depends on which effect
    # wins, but it must be monotone in the transient:
    tau_2dev = estimate_tau_max(pts, 100_000, n_shards=2,
                                tile_m=32, tile_n=32, seed=0)
    # a fatter resident tile eats more of the per-device budget
    tau_fat_tile = estimate_tau_max(pts, 100_000, n_shards=2,
                                    tile_m=48, tile_n=48, seed=0)
    assert tau_fat_tile <= tau_2dev
    # a tile transient bigger than the whole per-device budget is an error
    with pytest.raises(ValueError):
        estimate_tau_max(pts, 100_000, n_shards=2, tile_m=96, tile_n=96)
    # more devices at a generous per-device budget afford more global edges
    tau_4dev = estimate_tau_max(pts, 300_000, n_shards=4,
                                tile_m=64, tile_n=64, seed=0)
    tau_1dev_eq = estimate_tau_max(pts, 300_000 - tile_transient_bytes(
        64, 64, 4), seed=0)
    assert tau_4dev >= tau_1dev_eq


# ---------------------------------------------------------------------------
# compute_ph(..., mesh=...) end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2])
def test_compute_ph_mesh_matches_serial(n_devices):
    mesh = _data_mesh(n_devices)
    rng = np.random.default_rng(11)
    pts = rng.normal(size=(150, 3))
    got = compute_ph(points=pts, tau_max=1.2, maxdim=2, backend="tiled",
                     mesh=mesh, tile_m=64, tile_n=64)
    ref = compute_ph(points=pts, tau_max=1.2, maxdim=2)
    for dim in (0, 1, 2):
        assert np.array_equal(got.diagrams[dim], ref.diagrams[dim]), dim
    assert got.stats["n_shards"] == n_devices
    assert got.stats["per_device_peak_bytes"] > 0


def test_compute_ph_mesh_with_budget():
    mesh = _data_mesh(1)
    rng = np.random.default_rng(13)
    pts = rng.normal(size=(180, 3))
    res = compute_ph(points=pts, maxdim=1, backend="tiled", mesh=mesh,
                     memory_budget_bytes=150_000, tile_m=64, tile_n=64)
    assert "tau_max_estimated" in res.stats
    assert res.stats["per_device_base_bytes"] <= 1.15 * 150_000
    ref = compute_ph(points=pts, tau_max=res.stats["tau_max_estimated"],
                     maxdim=1)
    for dim in (0, 1):
        assert np.array_equal(res.diagrams[dim], ref.diagrams[dim])


def test_compute_ph_dense_rejects_mesh():
    pts = np.zeros((4, 2))
    with pytest.raises(ValueError):
        compute_ph(points=pts, backend="dense", mesh=object())
    # a prebuilt filtration can't be sharded either — reject, don't ignore
    filt = build_filtration(points=np.random.default_rng(0).normal(
        size=(10, 2)), tau_max=1.0)
    with pytest.raises(ValueError):
        compute_ph(filtration=filt, mesh=object())


def test_sharded_pallas_without_mesh_runs_pallas():
    """backend='pallas' + n_shards (no mesh) must actually run the f32
    candidate path per shard — not silently fall back to numpy while
    TileStats claims otherwise."""
    rng = np.random.default_rng(21)
    pts = rng.normal(size=(90, 3)) * 3.0
    dense = build_filtration(points=pts, tau_max=2.5)
    sharded, stats = build_filtration_sharded(
        points=pts, tau_max=2.5, tile_m=32, tile_n=32, n_shards=3,
        backend="pallas", interpret=True, return_stats=True)
    assert_filtrations_identical(dense, sharded, "host pallas shards")
    assert stats.backend == "pallas"
    assert stats.candidate_pairs >= dense.n_e   # the filter really ran


# ---------------------------------------------------------------------------
# budgeted reduction (first bite): h2 cap + pivot-store spill
# ---------------------------------------------------------------------------

def test_h2_columns_budget_cap_identical():
    from repro.core.homology import h2_columns, make_h1_adapter
    from repro.core.reduction import reduce_dimension

    rng = np.random.default_rng(6)
    pts = rng.normal(size=(40, 3))
    filt = build_filtration(points=pts, tau_max=1.5)
    adapter = make_h1_adapter(filt, sparse=True)
    cols1 = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    res1 = reduce_dimension(adapter, cols1, cleared=None)
    ref = h2_columns(filt, res1.pivot_lows, sparse=True)
    for budget in (1, 10_000, 10**9):
        got = h2_columns(filt, res1.pivot_lows, sparse=True,
                         memory_budget_bytes=budget)
        assert np.array_equal(ref, got), budget


def test_reduction_store_spill_same_diagrams():
    rng = np.random.default_rng(8)
    pts = rng.normal(size=(60, 3))
    ref = compute_ph(points=pts, tau_max=1.5, maxdim=2)
    capped = compute_ph(points=pts, tau_max=1.5, maxdim=2,
                        memory_budget_bytes=1_000, backend="dense")
    for dim in (0, 1, 2):
        assert np.array_equal(ref.diagrams[dim], capped.diagrams[dim]), dim
    assert capped.stats["h1_n_spilled"] > 0     # the cap actually engaged


def test_reduction_store_spill_sweep():
    """Mixed explicit/implicit stores must re-materialize *complete*
    δ-expansions: a spilled column that absorbed explicit-stored owners
    depends on their tracked gens (regression — a sweep like this caught
    incomplete expansions producing wrong addends)."""
    for seed in range(12):
        rng = np.random.default_rng(seed)
        pts = rng.normal(size=(36, 3))
        ref = compute_ph(points=pts, tau_max=1.8, maxdim=2)
        for budget in (200, 1_500):
            capped = compute_ph(points=pts, tau_max=1.8, maxdim=2,
                                memory_budget_bytes=budget, backend="dense")
            for dim in (0, 1, 2):
                assert np.array_equal(ref.diagrams[dim],
                                      capped.diagrams[dim]), (seed, budget,
                                                              dim)

"""Oracle-vs-engine equivalence for the Dory PH engine.

The textbook standard-reduction oracle (core/ref.py) defines ground truth;
every engine path (explicit/implicit x sparse/NS x single/batch) must produce
identical persistence diagrams.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import build_filtration, compute_ph, ref
from repro.core.diagrams import assert_diagrams_equal, canonicalize
from repro.core.h0 import compute_h0
from repro.core.homology import h2_columns, make_h1_adapter, make_h2_adapter
from repro.core.reduction import merge_cancel, parity_reduce, reduce_dimension
from repro.core.serial_parallel import reduce_dimension_batched
from repro.core import pairing


def random_cloud(seed, n=None, d=3):
    rng = np.random.default_rng(seed)
    n = n or int(rng.integers(6, 18))
    return rng.normal(size=(n, d))


# ---------------------------------------------------------------------------
# paired indexing
# ---------------------------------------------------------------------------

@given(kp=st.integers(0, 2**31 - 1), ks=st.integers(0, 2**31 - 1))
def test_pack_roundtrip(kp, ks):
    key = pairing.pack(kp, ks)
    kp2, ks2 = pairing.unpack(key)
    assert (int(kp2), int(ks2)) == (kp, ks)
    assert key != pairing.EMPTY_KEY


@given(st.lists(st.tuples(st.integers(0, 2**20), st.integers(0, 2**20)),
                min_size=2, max_size=20))
def test_pack_preserves_order(pairs):
    """Packed int64 comparison == paper eq. (1) lexicographic order."""
    keys = [int(pairing.pack(kp, ks)) for kp, ks in pairs]
    assert sorted(range(len(pairs)), key=lambda i: keys[i]) == \
        sorted(range(len(pairs)), key=lambda i: pairs[i])


# ---------------------------------------------------------------------------
# GF(2) column algebra
# ---------------------------------------------------------------------------

@given(st.data())
def test_merge_cancel_is_symmetric_difference(data):
    a = np.unique(np.array(
        data.draw(st.lists(st.integers(0, 99), max_size=30)), dtype=np.int64))
    b = np.unique(np.array(
        data.draw(st.lists(st.integers(0, 99), max_size=30)), dtype=np.int64))
    out = merge_cancel(a, b)
    expect = np.array(sorted(set(a.tolist()) ^ set(b.tolist())), dtype=np.int64)
    assert np.array_equal(out, expect)


@given(st.lists(st.integers(0, 20), max_size=40))
def test_parity_reduce(vals):
    keys = np.array(vals, dtype=np.int64)
    out = parity_reduce(keys)
    expect = sorted(v for v in set(vals) if vals.count(v) % 2 == 1)
    assert out.tolist() == expect


# ---------------------------------------------------------------------------
# full-pipeline equivalence vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["explicit", "implicit"])
@pytest.mark.parametrize("sparse", [True, False])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_engine_matches_oracle(mode, sparse, seed):
    pts = random_cloud(seed)
    tau = np.inf if seed % 2 == 0 else 1.6
    o = ref.standard_reduction_points(pts, tau_max=tau, maxdim=2)
    r = compute_ph(points=pts, tau_max=tau, maxdim=2, mode=mode, sparse=sparse)
    assert_diagrams_equal(r.diagrams, o, dims=[0, 1, 2])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), nd=st.integers(2, 4),
       finite_tau=st.booleans())
def test_engine_matches_oracle_hypothesis(seed, nd, finite_tau):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(int(rng.integers(5, 14)), nd))
    tau = float(rng.uniform(0.8, 2.5)) if finite_tau else np.inf
    o = ref.standard_reduction_points(pts, tau_max=tau, maxdim=2)
    r = compute_ph(points=pts, tau_max=tau, maxdim=2,
                   mode="implicit", sparse=bool(seed % 2))
    assert_diagrams_equal(r.diagrams, o, dims=[0, 1, 2])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), batch_size=st.sampled_from([2, 16, 64]))
def test_batched_equals_single(seed, batch_size):
    """Serial-parallel (§4.4) must equal the 1-thread engine exactly."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(int(rng.integers(8, 16)), 3))
    filt = build_filtration(points=pts, tau_max=np.inf)
    h0 = compute_h0(filt)
    cleared = set(int(e) for e in h0.death_edges)
    cols = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    a1 = make_h1_adapter(filt, sparse=True)
    single = reduce_dimension(a1, cols, mode="explicit", cleared=cleared)
    batched = reduce_dimension_batched(a1, cols, mode="implicit",
                                       cleared=cleared, batch_size=batch_size)
    assert np.array_equal(canonicalize(single.diagram()),
                          canonicalize(batched.diagram()))
    assert set(single.pivot_lows.tolist()) == set(batched.pivot_lows.tolist())


def test_h2_batched_full_pipeline():
    pts = random_cloud(42, n=16)
    o = ref.standard_reduction_points(pts, maxdim=2)
    r = compute_ph(points=pts, maxdim=2, engine="batch", batch_size=8,
                   mode="implicit")
    assert_diagrams_equal(r.diagrams, o, dims=[0, 1, 2])


def test_trivial_pairs_not_stored():
    """Paper §4.3.5: trivial pairs cost no pivot storage."""
    pts = random_cloud(7, n=16)
    filt = build_filtration(points=pts)
    h0 = compute_h0(filt)
    a1 = make_h1_adapter(filt, sparse=False)
    cols = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    res = reduce_dimension(a1, cols, mode="explicit",
                           cleared=set(int(e) for e in h0.death_edges))
    assert res.stats["n_stored_columns"] < res.stats["n_pairs"]


def test_implicit_stores_less_than_explicit():
    """Paper §4.3.1: storing V^⊥ instead of R^⊥ saves memory."""
    pts = random_cloud(11, n=24)
    exp = compute_ph(points=pts, maxdim=2, mode="explicit")
    imp = compute_ph(points=pts, maxdim=2, mode="implicit")
    assert imp.stats["h2_stored_bytes"] <= exp.stats["h2_stored_bytes"]
    assert_diagrams_equal(
        {k: canonicalize(v) for k, v in exp.diagrams.items()},
        {k: canonicalize(v) for k, v in imp.diagrams.items()}, dims=[1, 2])


def test_clearing_skips_columns():
    """H0 deaths are never reduced in H1*; H1* deaths never appear as H2*
    columns (Alg. 3)."""
    pts = random_cloud(3, n=14)
    filt = build_filtration(points=pts)
    h0 = compute_h0(filt)
    a1 = make_h1_adapter(filt, sparse=False)
    cols1 = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    res1 = reduce_dimension(a1, cols1, mode="explicit",
                            cleared=set(int(e) for e in h0.death_edges))
    # columns processed = n_e - #cleared
    assert res1.stats["n_pairs"] + res1.stats["n_essential"] == \
        filt.n_e - len(h0.death_edges)
    cols2 = h2_columns(filt, res1.pivot_lows, sparse=False)
    assert not (set(cols2.tolist()) & set(res1.pivot_lows.tolist()))


def test_base_memory_formula():
    """Paper appendix E: base memory = (3n + 12 n_e) * 4 bytes."""
    filt = build_filtration(points=random_cloud(0, n=20), tau_max=1.5)
    assert filt.base_memory_bytes() == (3 * filt.n + 12 * filt.n_e) * 4


def test_distance_matrix_input():
    pts = random_cloud(9, n=12)
    from repro.core.filtration import pairwise_distances
    o = compute_ph(points=pts, maxdim=1)
    r = compute_ph(dists=pairwise_distances(pts), maxdim=1)
    assert_diagrams_equal(o.diagrams, r.diagrams, dims=[0, 1])

"""System behaviour tests: gradient accumulation, checkpoint/restore,
compression, sharding rules, serving engine, data determinism, straggler /
failure policies, and the HLO roofline parser."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.train_step import TrainState, make_train_step

CFG = get_config("qwen3_0_6b", reduced=True)


# ---------------------------------------------------------------------------
# gradient accumulation
# ---------------------------------------------------------------------------

def _batch(b=8, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(
        rng.integers(0, CFG.vocab_size, (b, s + 1)).astype(np.int32))}


def test_grad_accum_equivalence():
    """n_micro=1 and n_micro=4 produce the same update (fp32 accumulation
    makes microbatching a pure re-bracketing of the mean)."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(1e-3, 2, 10))
    batch = _batch()
    outs = []
    for n_micro in (1, 4):
        step = jax.jit(make_train_step(CFG, opt, n_micro=n_micro))
        state = TrainState(params=params, opt=opt.init(params))
        new_state, metrics = step(state, batch)
        outs.append((new_state, metrics))
    (s1, m1), (s4, m4) = outs
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), s1.params, s4.params)
    assert max(jax.tree.leaves(diffs)) < 1e-5, \
        f"microbatching changed the update: {max(jax.tree.leaves(diffs))}"


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import Checkpointer
    params = init_params(CFG, jax.random.PRNGKey(0))
    ckpt = Checkpointer(str(tmp_path), keep=2)
    ckpt.save(3, params, metadata={"step": 3})
    ckpt.save_async(7, params, metadata={"step": 7})
    ckpt.wait()
    assert ckpt.all_steps() == [3, 7]
    restored, meta = ckpt.restore(params)
    assert meta["step"] == 7
    same = jax.tree.map(lambda a, b: bool((np.asarray(a) ==
                                           np.asarray(b)).all()),
                        params, restored)
    assert all(jax.tree.leaves(same))


def test_checkpoint_gc_and_atomicity(tmp_path):
    from repro.checkpoint import Checkpointer
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ckpt = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree)
    assert ckpt.all_steps() == [3, 4]          # keep=2 enforced
    # a stale .tmp dir from a crash must not corrupt/shadow anything
    os.makedirs(os.path.join(str(tmp_path), "step_0000000099.tmp"))
    assert ckpt.latest_step() == 4


def test_checkpoint_restore_resharded(tmp_path):
    """Checkpoint written unsharded restores onto an explicit sharding
    (the elastic re-mesh path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint import Checkpointer
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(0, tree, metadata={"step": 0})
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ckpt.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_ef_compression_bounded_error(seed):
    from repro.dist.compression import dequantize_int8, ef_compress
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=rng.uniform(0.01, 10),
                               size=(64,)).astype(np.float32))
    err = jnp.zeros_like(x)
    q, scale, new_err = ef_compress(x, err)
    # quantization error is bounded by half a quantization step...
    assert float(jnp.abs(new_err).max()) <= float(scale) * 0.5 + 1e-6
    # ...and feeding it back makes the *accumulated* signal unbiased
    deq = dequantize_int8(q, scale)
    np.testing.assert_allclose(np.asarray(deq + new_err), np.asarray(x),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_sharding_head_alignment_rules():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import spec_for_param

    rep = []
    # stacked (reps, d, out) weights; aligned q heads (16 % 16 == 0):
    # column-parallel on the out dim
    spec = spec_for_param("groups/0/attn/wq", (28, 1024, 2048), FakeMesh(),
                          rep, heads={"q": 16, "kv": 8})
    assert spec == P(None, "data", "model")
    # misaligned kv heads (8 % 16 != 0): row-parallel on d
    spec = spec_for_param("groups/0/attn/wk", (28, 1024, 1024), FakeMesh(),
                          rep, heads={"q": 16, "kv": 8})
    assert spec == P(None, "model", "data")
    # w_down: row-parallel over d_ff
    spec = spec_for_param("groups/0/ffn/w_down", (28, 3072, 1024),
                          FakeMesh(), rep)
    assert spec == P(None, "model", "data")
    # wo with aligned heads: row-parallel on the h*hd contraction
    spec = spec_for_param("groups/0/attn/wo", (28, 2048, 1024), FakeMesh(),
                          rep, heads={"q": 16, "kv": 8})
    assert spec == P(None, "model", "data")
    # MoE experts (stacked): expert dim over model
    spec = spec_for_param("groups/0/moe/w_up", (27, 64, 2048, 1408),
                          FakeMesh(), rep)
    assert spec[1] == "model"
    # embedding: vocab over model
    spec = spec_for_param("embed/table", (152064, 1024), FakeMesh(), rep)
    assert spec == P("model", "data")
    assert rep == []                        # nothing fell back


def test_activation_rules_decode_vs_train():
    from repro.dist.sharding import activation_rules

    cfg = get_config("qwen3_0_6b")
    train_rules = activation_rules(cfg, FakeMesh())
    assert train_rules["heads"] == "model"        # 16 q heads, aligned
    assert train_rules["kv_heads"] is None        # 8 kv heads, misaligned
    dec = activation_rules(cfg, FakeMesh(), decode=True, batch=128)
    assert dec["heads"] is None                   # cache stays seq-sharded
    assert dec["kv_seq"] == ("model",)
    long = activation_rules(cfg, FakeMesh(), decode=True, batch=1)
    assert long["batch"] is None                  # batch=1: all seq-parallel
    assert long["kv_seq"] == ("data", "model")


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serve_engine_completes_all_requests():
    from repro.serve.engine import Request, ServeEngine
    eng = ServeEngine(CFG, max_batch=4, prompt_len=8, s_max=32)
    rng = np.random.default_rng(0)
    for uid in range(6):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, CFG.vocab_size, 5)
                           .astype(np.int32), max_new=4))
    done = eng.run()
    assert sorted(done) == list(range(6))
    assert all(len(v) >= 4 for v in done.values())


def test_serve_engine_deterministic():
    from repro.serve.engine import Request, ServeEngine
    outs = []
    for _ in range(2):
        eng = ServeEngine(CFG, max_batch=2, prompt_len=8, s_max=32, seed=7)
        eng.submit(Request(uid=0, prompt=np.arange(5, dtype=np.int32),
                           max_new=6))
        outs.append(eng.run()[0])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# data pipeline determinism / reassignment
# ---------------------------------------------------------------------------

def test_token_stream_host_sharding_consistent():
    from repro.data.tokens import ShardedTokenStream
    full = ShardedTokenStream(vocab=101, global_batch=8, seq=12, seed=5)
    parts = [ShardedTokenStream(vocab=101, global_batch=8, seq=12, seed=5,
                                host_id=h, n_hosts=4) for h in range(4)]
    got = np.concatenate([p.batch_at(3)["tokens"] for p in parts])
    np.testing.assert_array_equal(got, full.batch_at(3)["tokens"])


@given(st.integers(2, 16), st.data())
@settings(max_examples=30, deadline=None)
def test_reassign_shards_total_coverage(n_hosts, data):
    from repro.data.tokens import reassign_shards
    failed = data.draw(st.lists(st.integers(0, n_hosts - 1), unique=True,
                                max_size=n_hosts - 1))
    mapping = reassign_shards(n_hosts, failed)
    covered = sorted(s for v in mapping.values() for s in v)
    assert covered == sorted(set(range(n_hosts)))           # all shards live
    assert set(mapping) == set(range(n_hosts)) - set(failed)


def test_straggler_policy():
    """ShardSupervisor detects the lagging shard on a deterministic clock
    and speculative_reassign duplicates its work onto the least-loaded
    survivor (the policy the packed reduction driver uses)."""
    from repro.launch.elastic import ShardSupervisor, speculative_reassign
    sup = ShardSupervisor(n_shards=4, timeout=100.0, factor=3.0)
    now = 10.0
    plan = sup.observe(now, beats={h: now - (2.0 if h == 2 else 0.1)
                                   for h in range(4)})
    assert plan.dead == []
    assert plan.stragglers == [2]
    assert plan.active == [0, 1, 3]          # sidelined, not dead
    assignment = {h: [i for i in range(16) if i % 4 == h] for h in range(4)}
    backups = speculative_reassign(assignment, plan.stragglers)
    assert 2 in backups
    assert set(assignment[backups[2]]) >= {2, 6, 10, 14}
    # the sideline expires: shard 2 beats on time next superstep
    later = now + sup.sideline + 1.0
    plan2 = sup.observe(later, beats={h: later for h in range(4)})
    assert plan2.active == [0, 1, 2, 3]


def test_shard_supervisor_death_is_permanent():
    from repro.launch.elastic import ShardSupervisor
    sup = ShardSupervisor(n_shards=4, timeout=1.5)
    # shard 3 stops beating at t=1; dead once lag > timeout
    for t in (1.0, 2.0, 3.0):
        plan = sup.observe(t, beats={h: t for h in range(4) if h != 3})
    assert 3 not in sup.live
    assert plan.active == [0, 1, 2]
    # it never comes back, even if a stale beat arrives
    plan = sup.observe(4.0, beats={h: 4.0 for h in range(4)})
    assert sup.live == [0, 1, 2] and plan.dead == []


# ---------------------------------------------------------------------------
# HLO roofline parser
# ---------------------------------------------------------------------------

def test_hlo_parser_on_real_lowering():
    """Parser vs XLA cost_analysis on a loop-free program."""
    from repro.launch.hlo import analyze_module

    def f(a, b):
        return jax.nn.relu(a @ b)

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    got = analyze_module(c.as_text(), pod_size=1)
    xla = c.cost_analysis()
    assert abs(got["flops"] - 2 * 128 * 256 * 64) < 2 * 128 * 64 + 1
    assert got["flops"] <= xla["flops"] <= got["flops"] * 1.05
    assert got["total"] == 0.0                       # no collectives


def test_hlo_parser_trip_weighting():
    """A lax.scan body must be charged trip-count times."""
    from repro.launch.hlo import analyze_module

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    got = analyze_module(c.as_text(), pod_size=1)
    one_matmul = 2 * 64 * 64 * 64
    assert got["flops"] >= 10 * one_matmul * 0.99, \
        f"scan body not trip-weighted: {got['flops']} vs {10 * one_matmul}"
    xla = c.cost_analysis()
    assert xla["flops"] < got["flops"]     # XLA counts the body once


def test_hlo_ring_formulas():
    from repro.launch.hlo import _ring_bytes
    assert _ring_bytes("all-reduce", 100, 4) == pytest.approx(150.0)
    assert _ring_bytes("all-gather", 100, 4) == pytest.approx(75.0)
    assert _ring_bytes("reduce-scatter", 100, 4) == pytest.approx(300.0)
    assert _ring_bytes("all-to-all", 100, 4) == pytest.approx(75.0)
    assert _ring_bytes("collective-permute", 100, 4) == pytest.approx(100.0)
    assert _ring_bytes("all-reduce", 100, 1) == 0.0


def test_compressed_psum_reduces_collective_bytes():
    """The int8 EF compressed gradient exchange must move ~4x fewer bytes
    over the pod (N=2, DCN) axis than the f32 psum, and produce the same
    mean up to quantization error (HLO + numeric proof, forced devices)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist.compression import compressed_psum_grads
from repro.launch.hlo import analyze_module
mesh = Mesh(np.array(jax.devices()[:2]).reshape(2,), ("pod",))
g = jax.ShapeDtypeStruct((1024, 256), jnp.float32)

def plain(grads):
    return jax.lax.psum(grads, "pod") / jax.lax.psum(1, "pod")

def compressed(grads):
    out, _ = compressed_psum_grads({"g": grads},
                                   {"g": jnp.zeros(grads.shape, jnp.float32)},
                                   "pod")
    return out["g"]

def build(fn):
    sm = jax.shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    return jax.jit(sm).lower(g).compile()

c_plain, c_comp = build(plain), build(compressed)
b_plain = analyze_module(c_plain.as_text(), pod_size=2)["total"]
b_comp = analyze_module(c_comp.as_text(), pod_size=2)["total"]
print("BYTES", b_plain, b_comp)
assert b_comp < b_plain / 2.5, (b_plain, b_comp)
x = np.random.default_rng(0).normal(size=(1024, 256)).astype(np.float32)
got = np.asarray(c_comp(x)["g"]) if isinstance(c_comp(x), dict) else np.asarray(c_comp(x))
want = np.asarray(c_plain(x))
err = np.abs(got - want).max()
assert err < np.abs(x).max() / 127 + 1e-5, err
print("COMPRESSION_OK", b_plain / max(b_comp, 1))
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(
                   os.path.dirname(os.path.abspath(__file__))), "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "COMPRESSION_OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-2000:]


# ---------------------------------------------------------------------------
# end-to-end: elastic failure -> re-mesh -> restore (subprocess: needs 8
# forced host devices)
# ---------------------------------------------------------------------------

def test_elastic_remesh_restore(tmp_path):
    """Full failure -> re-mesh -> restore -> continue cycle: train on
    (data=4, model=2) with per-step checkpoints, kill half the devices
    (heartbeat detects hosts 2,3 dead), rebuild (2,2) from survivors,
    restore resharded, and keep training across the boundary."""
    code = f"""
from repro.launch.elastic import Heartbeat
from repro.checkpoint import Checkpointer  # noqa: F401 (restore path)
from repro.configs import get_config
from repro.data.tokens import reassign_shards
from repro.launch.train import TrainJob, run

cfg = get_config("qwen3-0.6b", reduced=True)
ckpt_dir = r'{tmp_path}'
job = TrainJob(cfg=cfg, steps=3, global_batch=4, seq_len=16,
               ckpt_dir=ckpt_dir, ckpt_every=1, mesh_shape=(4, 2),
               log_every=1)
out1 = run(job)

hb = Heartbeat(timeout_s=0.5)
now = 100.0
for h in range(4):
    hb.beat(h, now - (10.0 if h >= 2 else 0.0))
dead = sorted(hb.dead(now))
assert dead == [2, 3], dead
mapping = reassign_shards(4, dead)
assert mapping == {{0: [0, 2], 1: [1, 3]}}, mapping

job2 = TrainJob(cfg=cfg, steps=6, global_batch=4, seq_len=16,
                ckpt_dir=ckpt_dir, ckpt_every=10_000, mesh_shape=(2, 2),
                log_every=1)
out2 = run(job2, restore=True)
assert len(out2["history"]) > 0
print("ELASTIC_OK", out2["final_loss"])
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(
                   os.path.dirname(os.path.abspath(__file__))), "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert "ELASTIC_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]


def test_tda_monitor_on_hidden_states():
    """The Dory engine runs as a training-time monitor on the model's own
    representations (the paper's technique as a first-class framework
    feature)."""
    from repro.launch.train import tda_monitor
    from repro.models.transformer import init_params
    params = init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, CFG.vocab_size, (4, 17))
             .astype(np.int32)}
    out = tda_monitor(params, CFG, batch)
    assert out["tda_h0_pairs"] > 0
    assert np.isfinite(list(out.values())).all()

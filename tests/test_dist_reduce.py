"""Distributed packed reduction (``repro.core.packed_reduce`` + the shared
``repro.core.pivot_cache``): bit-identity across shard counts, transports,
modes, cadences and store budgets.

The contract is the tentpole invariant: partitioning the column batches of
a dimension over ``P`` shards — concurrent phases against a replica pivot
store fed by Elias–Fano wire payloads, tournament catch-up, exact commit
sweeps — must produce diagrams **bit-identical** to every single-device
engine, for every ``P``, exchange cadence and storage mode.  The
host-partitioned driver reproduces any device count's work split without
devices, so the identity sweep always runs; the mesh-collective transport
is parametrized over 1/2/4 virtual devices and skips counts the process
doesn't have (CI's ``reduce-bench-4dev`` job runs them all under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

Also here: the pivot-cache memo/codec property tests (S1) and the
near-clique coboundary fast-path guard (dense-grid tie-heavy identity).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import build_filtration, compute_ph
from repro.core.coboundary import edge_cobdy_ns, edge_cobdy_sparse
from repro.core.diagrams import assert_diagrams_equal
from repro.core.pairing import EMPTY_KEY
from repro.core.pivot_cache import (PackedPivotCache, decode_commit_delta,
                                    encode_commit_delta)
from repro.data.pointclouds import fractal_like

DIMS = (0, 1, 2)


def tie_heavy_cloud(seed, n=16):
    """Integer grid points: many exactly-equal pairwise distances, the
    adversarial regime for any ordering-sensitive reduction schedule."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 4, size=(n, 3)).astype(np.float64)


def _data_mesh(n_devices):
    import jax

    if len(jax.devices()) < n_devices:
        pytest.skip(f"needs {n_devices} devices (run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={n_devices})")
    from repro.launch.mesh import make_data_mesh
    return make_data_mesh(n_devices)


def _assert_same_diagrams(ref, got, label):
    for dim in DIMS:
        assert np.array_equal(ref.diagrams[dim], got.diagrams[dim]), \
            (label, dim)


# ---------------------------------------------------------------------------
# bit-identity sweep: host-partitioned shards (any count, no devices needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["explicit", "implicit"])
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_dist_packed_matches_single(mode, n_shards):
    dists = fractal_like(40, seed=3)
    ref = compute_ph(dists=dists, maxdim=2, engine="single", mode=mode)
    got = compute_ph(dists=dists, maxdim=2, engine="packed", mode=mode,
                     n_shards=n_shards, batch_size=64)
    _assert_same_diagrams(ref, got, f"P={n_shards} {mode}")
    assert got.stats["h1_n_shards"] == n_shards
    if n_shards > 1:   # H2 is the long pass: exchanges must really happen
        rounds = got.stats["h1_n_exchange_rounds"] \
            + got.stats["h2_n_exchange_rounds"]
        wire = got.stats["h1_exchange_bytes"] + got.stats["h2_exchange_bytes"]
        assert rounds >= 1 and wire > 0


@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_dist_packed_tie_heavy(mode):
    """Exactly-equal filtration values: the canonical-pairing argument says
    any left-to-right GF(2) schedule pairs identically — the distributed
    schedule included.  Ties are where a wrong tie-break would show."""
    pts = tie_heavy_cloud(5, n=18)
    ref = compute_ph(points=pts, maxdim=2, engine="single", mode=mode)
    for P in (2, 3):
        got = compute_ph(points=pts, maxdim=2, engine="packed", mode=mode,
                         n_shards=P, batch_size=32)
        _assert_same_diagrams(ref, got, f"ties P={P} {mode}")


@pytest.mark.parametrize("budget", [None, 4096])
@pytest.mark.parametrize("n_shards", [2, 4])
def test_dist_packed_store_budget(n_shards, budget):
    """Spill-to-implicit under a store budget must not perturb distributed
    diagrams (spill decisions are per-store, replicas included)."""
    dists = fractal_like(36, seed=9)
    ref = compute_ph(dists=dists, maxdim=2, engine="single")
    got = compute_ph(dists=dists, maxdim=2, engine="packed",
                     n_shards=n_shards, batch_size=48,
                     memory_budget_bytes=budget)
    _assert_same_diagrams(ref, got, f"P={n_shards} budget={budget}")


@pytest.mark.parametrize("exchange_every", [1, 3, 8])
def test_dist_packed_cadence_independent(exchange_every):
    """Diagrams can't depend on how many supersteps ride between pivot
    exchanges — the cadence only moves wall time and wire bytes."""
    dists = fractal_like(36, seed=11)
    ref = compute_ph(dists=dists, maxdim=2, engine="packed", n_shards=1)
    got = compute_ph(dists=dists, maxdim=2, engine="packed", n_shards=4,
                     mode="implicit", batch_size=48,
                     exchange_every=exchange_every)
    _assert_same_diagrams(ref, got, f"ee={exchange_every}")


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), n_shards=st.integers(1, 5),
       mode=st.sampled_from(["explicit", "implicit"]))
def test_dist_packed_hypothesis(seed, n_shards, mode):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(int(rng.integers(10, 26)), 3))
    ref = compute_ph(points=pts, maxdim=2, engine="single", mode=mode)
    got = compute_ph(points=pts, maxdim=2, engine="packed", mode=mode,
                     n_shards=n_shards, batch_size=16)
    _assert_same_diagrams(ref, got, f"hyp P={n_shards} {mode}")


# ---------------------------------------------------------------------------
# bit-identity: mesh-collective transport (1/2/4 virtual devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_dist_packed_mesh_bit_identical(n_devices):
    """Same work split as the host driver, but the pivot exchange really
    cross-ships through ``jax.lax.all_gather`` under ``shard_map``."""
    mesh = _data_mesh(n_devices)
    dists = fractal_like(40, seed=3)
    ref = compute_ph(dists=dists, maxdim=2, engine="single")
    got = compute_ph(dists=dists, maxdim=2, engine="packed", mesh=mesh,
                     batch_size=64, mode="implicit")
    _assert_same_diagrams(ref, got, f"mesh[{n_devices}]")
    assert got.stats["h1_n_shards"] == n_devices


def test_dist_packed_mesh_vs_host_same_split():
    """Mesh transport and the host loop-back are the same partition: every
    counter that describes the work split must agree exactly."""
    mesh = _data_mesh(2)
    dists = fractal_like(36, seed=7)
    a = compute_ph(dists=dists, maxdim=2, engine="packed", mesh=mesh,
                   batch_size=48)
    b = compute_ph(dists=dists, maxdim=2, engine="packed", n_shards=2,
                   batch_size=48)
    _assert_same_diagrams(a, b, "mesh vs host")
    for k in ("h1_n_supersteps", "h1_n_tournament_reductions",
              "h2_n_supersteps", "h2_n_tournament_reductions",
              "h1_n_reductions", "h2_n_reductions"):
        assert a.stats[k] == b.stats[k], k


# ---------------------------------------------------------------------------
# pivot cache: memo + codec properties (S1)
# ---------------------------------------------------------------------------

def test_cache_position_memo_epoch_invalidates():
    cache = PackedPivotCache()
    pos = np.array([3, 17, 64], dtype=np.int64)
    assert cache.get_positions(7) is None
    cache.put_positions(7, pos)
    np.testing.assert_array_equal(cache.get_positions(7), pos)
    assert cache.n_packs == 1 and cache.n_pack_hits == 1
    cache.bump_epoch()                      # layout changed: memo is stale
    assert cache.get_positions(7) is None
    assert cache.n_pack_hits == 1           # a miss is not a hit


def test_cache_column_memo_fifo_budget():
    cache = PackedPivotCache(budget_bytes=3 * 8 * 4)   # room for ~3 columns
    for low in range(6):
        cache.put_column(low, np.arange(4, dtype=np.int64))
    assert cache.column_bytes <= 3 * 8 * 4
    assert cache.n_col_evictions >= 3
    assert cache.get_column(0) is None      # FIFO: oldest went first
    assert cache.get_column(5) is not None  # newest survives


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_commit_delta_roundtrip(seed):
    """The replication codec is lossless for any mix of explicit/implicit
    records, including empty columns and empty deltas."""
    rng = np.random.default_rng(seed)
    records = []
    for i in range(int(rng.integers(0, 12))):
        mode = "explicit" if rng.integers(2) else "implicit"
        keys = np.unique(rng.integers(0, 2**40, size=rng.integers(0, 30))
                            .astype(np.int64))
        records.append({
            "low": int(rng.integers(0, 2**40)),
            "col_id": int(rng.integers(0, 2**32)),
            "mode": mode,
            "column": keys if mode == "explicit" else None,
            "gens": rng.integers(0, 2**31, size=rng.integers(0, 9))
                       .astype(np.int64),
        })
    back = decode_commit_delta(encode_commit_delta(records))
    assert len(back) == len(records)
    for r, g in zip(records, back):
        assert g["low"] == r["low"] and g["col_id"] == r["col_id"]
        assert g["mode"] == r["mode"]
        if r["mode"] == "explicit":
            np.testing.assert_array_equal(g["column"], r["column"])
        else:
            assert g["column"] is None
        np.testing.assert_array_equal(g["gens"], np.sort(r["gens"]))


def test_cache_hit_rate_on_workload():
    """The S1 contract: with the shared cache each stored pivot is packed
    about once — packs stay bounded by the stored-pivot count (cleared
    columns never pack at all) instead of growing with consumer count."""
    dists = fractal_like(48, seed=0)
    res = compute_ph(dists=dists, maxdim=2, engine="packed",
                     mode="implicit", batch_size=64)
    s = res.stats
    for dim in ("h1", "h2"):
        packs = s[f"{dim}_cache_n_packs"]
        stored = s[f"{dim}_n_stored_columns"] + s[f"{dim}_n_spilled"]
        assert packs <= stored + 1, (dim, packs, stored)
        # each committed pivot's column is enumerated at most once (the
        # memo absorbs every later request; without it the count grows
        # with the number of consuming rounds)
        mats = s[f"{dim}_cache_n_materializations"]
        assert mats <= s[f"{dim}_n_pairs"] + 1, (dim, mats)
    # and the memo really serves repeat requests on the long pass
    assert s["h2_cache_n_mat_hits"] > 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_wire_payload_stack_roundtrip(seed):
    """The collective wire buffer is lossless and power-of-two bucketed."""
    from repro.kernels.gf2 import stack_wire_payloads, unstack_wire_payloads

    rng = np.random.default_rng(seed)
    payloads = [rng.integers(0, 2**32, size=rng.integers(0, 3000),
                             dtype=np.uint64).astype(np.uint32)
                for _ in range(int(rng.integers(1, 6)))]
    buf, lens = stack_wire_payloads(payloads, min_words=64)
    L = buf.shape[1]
    assert L & (L - 1) == 0 and L >= max(64, max(lens, default=1))
    back = unstack_wire_payloads(buf, lens)
    for a, b in zip(payloads, back):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# near-clique coboundary fast path (S2): dense-grid identity guard
# ---------------------------------------------------------------------------

def test_edge_cobdy_ns_matches_sparse_rows():
    """The compacted case-1/case-2 assembly must emit exactly the sorted
    key rows the old full-row sort produced — checked against the sparse
    path, which sorts unconditionally."""
    pts = tie_heavy_cloud(2, n=20)          # grid: near-clique neighborhoods
    filt = build_filtration(points=pts, tau_max=np.inf)
    orders = np.arange(filt.n_e, dtype=np.int64)
    ns = edge_cobdy_ns(filt, orders)
    sp = edge_cobdy_sparse(filt, orders)
    for r in range(filt.n_e):
        a = ns[r][ns[r] != EMPTY_KEY]
        b = sp[r][sp[r] != EMPTY_KEY]
        np.testing.assert_array_equal(a, b)
        assert np.all(np.diff(a) > 0)       # strictly ascending, no dupes


@pytest.mark.parametrize("engine", ["single", "packed"])
def test_dense_grid_ns_vs_sparse_diagrams(engine):
    """End-to-end guard: the NS (dense order) pipeline with the fast path
    and the order-free sparse pipeline agree on a tie-heavy grid."""
    pts = tie_heavy_cloud(4, n=16)
    ns = compute_ph(points=pts, maxdim=2, engine=engine, sparse=False)
    sp = compute_ph(points=pts, maxdim=2, engine=engine, sparse=True)
    _assert_same_diagrams(ns, sp, f"ns vs sparse [{engine}]")


# ---------------------------------------------------------------------------
# dists matrix through the sharded device tile path (S3)
# ---------------------------------------------------------------------------

def test_sharded_device_dists_bit_identical():
    from repro.scale import build_filtration_sharded, build_filtration_tiled

    mesh = _data_mesh(2)
    rng = np.random.default_rng(2)
    pts = rng.normal(size=(80, 3))
    d = np.sqrt(((pts[:, None] - pts[None, :]) ** 2).sum(-1))
    np.fill_diagonal(d, 0.0)
    tau = float(np.quantile(d[np.triu_indices(80, k=1)], 0.4))
    ref = build_filtration_tiled(dists=d, tau_max=tau, tile_m=32, tile_n=32)
    got, st_ = build_filtration_sharded(dists=d, tau_max=tau, tile_m=32,
                                        tile_n=32, mesh=mesh,
                                        return_stats=True)
    assert np.array_equal(ref.edges, got.edges)
    assert np.array_equal(ref.edge_len, got.edge_len)
    assert st_.gather_bytes > 0             # the device rounds really ran
